"""Paper Figures 7, 8, 9: model-selection behaviour, active model counts
across bias levels, and the score-σ trajectory.

Fig 7: consensus preferred model per archetype over rounds — devices
should segregate by meta-archetype after the first milestone.
Fig 8/9: number of active (device, model) preferences and mean score σ,
swept over device bias ∈ {0.2 (IID-within-meta), 0.45, 0.65, 0.9}.

``--compare-engines`` instead times the three round engines (fused /
batched / legacy) on identical seeded runs and reports steady-state
per-round speedups. The scenario is the regime FedCD actually spends
thousands of rounds in: 30 devices at 10% participation (McMahan et
al.'s C=0.1), a multi-model population (milestones 1-3 → 6+ live
models), preferences segregated by the late-deletion rule, measured
both with int8 transport quantization (paper §3.4) and without.
``--quick`` shrinks it to a CI smoke (10 devices, fewer rounds).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# --mesh S wants S simulated devices; the XLA flag only takes effect
# before jax first initializes, so inject it when this module IS the
# program (python -m benchmarks.bench_model_dynamics --mesh 4). Under
# benchmarks.run, jax is already up — set XLA_FLAGS in the environment
# instead (CI's sharded leg does).
def _mesh_argv(argv):
    for k, a in enumerate(argv):
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
        if a == "--mesh" and k + 1 < len(argv):
            return argv[k + 1]
    return None


_n = _mesh_argv(sys.argv)
if _n is None and "--data-mesh" in sys.argv:
    _n = "4"                      # the 2x2-vs-4x1 comparison's budget
if _n is not None and _n.isdigit() and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

import numpy as np

from benchmarks import common as C
from repro.core.fedcd import FedCDServer
from repro.core.spec import EngineSpec


def run(rounds: int = 30, model: str = "mlp", force: bool = False):
    name = f"fig789_dynamics_{model}_{rounds}"
    cached = None if force else C.load_result(name)
    if cached is None:
        params, loss_fn, acc_fn = C.model_fns(model)
        by_bias = {}
        preferred = None
        metas = None
        for bias in (0.2, 0.45, 0.65, 0.9):
            devs, data = C.make_data("hierarchical", seed=0, bias=bias)
            cfg = C.default_cfg(milestones=(5, 15, 25))
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH)
            srv.run(rounds)
            by_bias[str(bias)] = {
                "active_models": [m.active_models for m in srv.metrics],
                "live_models": [m.live_models for m in srv.metrics],
                "score_std": [m.score_std for m in srv.metrics],
            }
            if bias == 0.65:
                preferred = [m.preferred.tolist() for m in srv.metrics]
                metas = [d.archetype // 5 for d in devs]
        cached = {"rounds": rounds, "by_bias": by_bias,
                  "preferred": preferred, "metas": metas}
        C.save_result(name, cached)

    # Fig 7 segregation purity at the end (bias 0.65 run)
    pref = np.array(cached["preferred"][-1])
    metas = np.array(cached["metas"])
    purity = 0.0
    for meta in (0, 1):
        p = pref[metas == meta]
        purity += np.max(np.bincount(p)) / len(p) / 2
    lines = [C.csv_line("fig7_meta_segregation_purity", 0.0,
                        f"purity={purity:.3f}")]
    for bias, r in cached["by_bias"].items():
        lines.append(C.csv_line(
            f"fig8_active_models_bias{bias}", 0.0,
            f"peak={max(r['active_models'])};final={r['active_models'][-1]};"
            f"final_live={r['live_models'][-1]}"))
        lines.append(C.csv_line(
            f"fig9_score_std_bias{bias}", 0.0,
            f"peak={max(r['score_std']):.3f};final={r['score_std'][-1]:.3f}"))
    return lines


def compare_engines(rounds: int = 20, model: str = "mlp",
                    quick: bool = False):
    """Time fused vs batched vs legacy on identical seeded runs.

    Steady state = the median per-round wall over the back half of the
    run, after the milestones (rounds 1-3) have grown the population to
    6+ live models, every work-batch bucket is compiled, and the
    late-deletion rule has segregated device preferences — the regime a
    long FedCD study spends almost all its rounds in. Reported for both
    int8 transport quantization (paper §3.4, the device-memory story)
    and uncompressed transport.
    """
    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 8)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=2,
                    milestones=(1, 2), late_delete_round=3,
                    local_epochs=1)
    else:
        rounds = max(rounds, 12)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        # 10% participation (McMahan et al.'s C=0.1), three milestones
        base = dict(devices_per_round=3, milestones=(1, 2, 3),
                    late_delete_round=5, local_epochs=1)

    lines = []
    variants = [("int8", 8)] if quick else [("int8", 8), ("fp32", 0)]
    for tag, bits in variants:
        cfg = C.default_cfg(quantize_bits=bits, **base)
        servers = {}
        total = {}
        for engine in ("legacy", "batched", "fused"):
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH, spec=engine)
            t0 = time.time()
            srv.run(rounds)
            total[engine] = time.time() - t0
            servers[engine] = srv

        live = [m.live_models for m in servers["fused"].metrics]
        steady = list(range(rounds // 2 + 1, rounds + 1))
        med = {e: float(np.median([servers[e].metrics[t - 1].wall_s
                                   for t in steady])) for e in servers}
        fused_x = med["batched"] / max(med["fused"], 1e-12)
        batched_x = med["legacy"] / max(med["batched"], 1e-12)
        for engine in ("fused", "batched", "legacy"):
            lines.append(C.csv_line(
                f"engine_round_wall_{engine}_{tag}", med[engine] * 1e6,
                f"rounds={rounds};steady_live={live[-1]};"
                f"devices={cfg.n_devices}"))
        lines.append(C.csv_line(
            f"engine_speedup_{tag}", 0.0,
            f"fused_over_batched={fused_x:.2f}x;"
            f"batched_over_legacy={batched_x:.2f}x;"
            f"total_fused_s={total['fused']:.2f};"
            f"total_batched_s={total['batched']:.2f};"
            f"total_legacy_s={total['legacy']:.2f}"))
        # smoke check: the engines must agree on the population dynamics
        # (under int8 transport, float noise at quantization boundaries
        # may flip individual device preferences late in a long run, but
        # the population trajectory itself must match)
        for engine in ("legacy", "batched"):
            other = [m.live_models for m in servers[engine].metrics]
            if other != live:
                raise AssertionError(
                    f"engine divergence ({tag}): {engine} live={other} "
                    f"fused={live}")
    return lines


def compare_mesh(rounds: int = 16, model: str = "mlp", shards: int = 4,
                 quick: bool = False):
    """Time the mesh-sharded fused engine against single-device fused at
    equal population (DESIGN.md §9).

    The scenario targets the sharding regime: four early milestones grow
    the population to 8+ live models (each resident on its row shard)
    with deletions pushed past the horizon, so every round carries a
    multi-shard work batch. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or the
    ``--mesh N`` CLI shortcut, which sets it before jax initializes) to
    get N simulated devices; ``shards`` is clamped to the devices that
    actually exist, and ``shards=1`` measures pure shard_map overhead
    (the no-slower-than-fused check)."""
    import jax

    from repro.launch.mesh import make_model_mesh

    m_cap = 16
    avail = jax.device_count()
    want = shards
    shards = min(shards, avail)
    while m_cap % shards:        # bank rows must divide over the mesh
        shards -= 1
    if shards != want:
        print(f"# --mesh {want} clamped to {shards} "
              f"({avail} local devices, max_models={m_cap})")
    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 8)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=4,
                    local_epochs=1)
    else:
        rounds = max(rounds, 12)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        base = dict(devices_per_round=6, local_epochs=1)
    cfg = C.default_cfg(quantize_bits=8, max_models=m_cap,
                        milestones=(1, 2, 3, 4),
                        late_delete_round=rounds + 5, **base)

    servers = {}
    total = {}
    # shards may have clamped to 1 (pure shard_map overhead): inject the
    # 1x1 mesh so the sharded plane still runs — the string presets
    # can't spell that, EngineSpec(mesh=...) can
    for tag, spec in (("single", EngineSpec()),
                      (f"shard{shards}",
                       EngineSpec(model_shards=shards,
                                  mesh=make_model_mesh(shards)))):
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, spec=spec)
        t0 = time.time()
        srv.run(rounds)
        total[tag] = time.time() - t0
        servers[tag] = srv

    live = [m.live_models for m in servers["single"].metrics]
    steady = list(range(rounds // 2 + 1, rounds + 1))
    med = {t: float(np.median([servers[t].metrics[r - 1].wall_s
                               for r in steady])) for t in servers}
    tag = f"shard{shards}"
    speedup = med["single"] / max(med[tag], 1e-12)
    lines = []
    for t in ("single", tag):
        lines.append(C.csv_line(
            f"mesh_round_wall_{t}", med[t] * 1e6,
            f"rounds={rounds};steady_live={live[-1]};"
            f"devices={cfg.n_devices};jax_devices={avail}"))
    lines.append(C.csv_line(
        "mesh_speedup", 0.0,
        f"sharded_over_single={speedup:.2f}x;shards={shards};"
        f"steady_live={live[-1]};total_single_s={total['single']:.2f};"
        f"total_sharded_s={total[tag]:.2f}"))
    # the sharded engine must be a pure layout refactor: identical
    # population dynamics on the same seed
    other = [m.live_models for m in servers[tag].metrics]
    if other != live:
        raise AssertionError(
            f"mesh divergence: sharded live={other} single={live}")
    return lines


def compare_datamesh(rounds: int = 12, model: str = "mlp",
                     quick: bool = False):
    """Time the 2-D (model × data) mesh against the 1-D model mesh at
    EQUAL device count (DESIGN.md §11): 2×2 vs 4×1 on 4 simulated
    devices, plus a churn-regime row (random join/leave/drift schedule
    under the 2-D mesh vs single-device fused).

    Beyond wall clock, the rows record the quantity the data axis
    exists for: per-shard resident DEVICE-SPLIT bytes, which shrink
    S_data× once splits stop being replicated per model shard — the
    memory headroom that lifts the population cap toward the ROADMAP's
    "millions of users" scale. Run under ``XLA_FLAGS=--xla_force_host_
    platform_device_count=4`` (or the ``--mesh 4`` CLI shortcut)."""
    import jax

    from repro.data.scenarios import random_churn

    avail = jax.device_count()
    if avail < 2:
        print(f"# --data-mesh needs >=2 devices, have {avail}: skipping "
              f"(a 1x1-vs-1x1 'comparison' would be meaningless)")
        return []
    if avail < 4:
        print(f"# --data-mesh needs 4 devices, have {avail}: "
              f"falling back to (2x1) vs (1x2)")
    sm = 2 if avail >= 4 else 1
    sd = 2
    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 8)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=4,
                    local_epochs=1)
    else:
        rounds = max(rounds, 12)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        base = dict(devices_per_round=6, local_epochs=1)
    cfg = C.default_cfg(quantize_bits=8, max_models=16,
                        milestones=(1, 2, 3, 4),
                        late_delete_round=rounds + 5, **base)

    variants = [("mesh1d", f"sharded@{sm * sd}"),
                ("mesh2d", f"sharded@{sm}x{sd}")]
    servers = {}
    total = {}
    for tag, spec in variants:
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, spec=spec)
        t0 = time.time()
        srv.run(rounds)
        total[tag] = time.time() - t0
        servers[tag] = srv

    live = [m.live_models for m in servers["mesh1d"].metrics]
    steady = list(range(rounds // 2 + 1, rounds + 1))
    med = {t: float(np.median([servers[t].metrics[r - 1].wall_s
                               for r in steady])) for t in servers}
    lines = []
    for tag, _ in variants:
        mesh = servers[tag].mesh
        bank = servers[tag].executor.databank
        lines.append(C.csv_line(
            f"datamesh_round_wall_{tag}", med[tag] * 1e6,
            f"rounds={rounds};steady_live={live[-1]};"
            f"devices={cfg.n_devices};"
            f"mesh={mesh.shape.get('model', 1)}x"
            f"{mesh.shape.get('data', 1)};"
            f"data_bytes_per_shard={bank.bytes_per_shard()}"))
    b1 = servers["mesh1d"].executor.databank.bytes_per_shard()
    b2 = servers["mesh2d"].executor.databank.bytes_per_shard()
    lines.append(C.csv_line(
        "datamesh_speedup", 0.0,
        f"mesh2d_over_mesh1d={med['mesh1d'] / max(med['mesh2d'], 1e-12):.2f}x;"
        f"data_bytes_shrink={b1 / max(b2, 1):.2f}x;"
        f"total_mesh1d_s={total['mesh1d']:.2f};"
        f"total_mesh2d_s={total['mesh2d']:.2f}"))
    # the 2-D mesh must stay a pure layout refactor
    other = [m.live_models for m in servers["mesh2d"].metrics]
    if other != live:
        raise AssertionError(
            f"datamesh divergence: 2d live={other} 1d={live}")

    # churn regime: a dynamic population under the 2-D mesh vs the
    # single-device fused engine on the SAME schedule
    def sched():
        return random_churn(rounds, cfg.n_devices, seed=1, join_rate=0.4,
                            leave_rate=0.3, drift_rate=0.2,
                            min_devices=max(4, cfg.devices_per_round),
                            n_train=C.N_TRAIN, n_val=C.N_VAL,
                            n_test=C.N_TEST)
    churn = {}
    for tag, spec in (
            ("fused", EngineSpec(scenario=sched())),
            ("mesh2d", EngineSpec(model_shards=sm, data_shards=sd,
                                  scenario=sched()))):
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, spec=spec)
        t0 = time.time()
        srv.run(rounds)
        churn[tag] = (time.time() - t0, srv)
    ev = sched()
    ref_live = [m.live_models for m in churn["fused"][1].metrics]
    mesh_live = [m.live_models for m in churn["mesh2d"][1].metrics]
    if ref_live != mesh_live:
        raise AssertionError(
            f"churn divergence: mesh2d live={mesh_live} fused={ref_live}")
    lines.append(C.csv_line(
        "datamesh_churn_round_wall", churn["mesh2d"][0] / rounds * 1e6,
        f"fused_us={churn['fused'][0] / rounds * 1e6:.0f};"
        f"events={len(ev.events)};joins={ev.total_joins};"
        f"final_present={int(churn['mesh2d'][1].present.sum())};"
        f"rounds={rounds}"))
    return lines


def compare_pipeline(rounds: int = 16, model: str = "mlp",
                     shards: int = 4, quick: bool = False):
    """Time cross-round pipelined dispatch (DESIGN.md §10) against the
    synchronous engines: sync sharded (the PR 3 engine), pipelined
    sharded, sync fused, and pipelined fused, on identical seeded runs
    in the dynamic regime (early milestones growing the population,
    eq-4 deletions live) where the monolithic round program's shape key
    churns. Pipelining wins by (a) dispatching round t+1's training
    speculatively while round t's eval matrices are in flight and (b)
    keeping the split phases' shape keys stable, so retraces overlap
    device work instead of idling it. The plan-repair/invalidation
    rates are reported alongside the speedups.

    NOTE the CPU backend serializes dependent dispatch of multi-shard
    arrays at argument commit (measured; single-device dispatch chains
    stay fully async), so the sharded+pipelined combination mostly
    shows the split-phase retrace win here — the full overlap shows in
    the single-device pipelined row and needs a stream-ordered
    accelerator backend to compose with sharding."""
    import jax

    from repro.launch.mesh import make_model_mesh

    m_cap = 16
    avail = jax.device_count()
    want = shards
    shards = min(shards, avail)
    while m_cap % shards:
        shards -= 1
    if shards != want:
        print(f"# --pipeline: --mesh {want} clamped to {shards} "
              f"({avail} local devices, max_models={m_cap})")
    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 10)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=4,
                    local_epochs=1)
    else:
        rounds = max(rounds, 16)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        base = dict(devices_per_round=6, local_epochs=1)
    # milestones AND late deletions inside the horizon: the population
    # keeps changing, so the monolithic engines' (B, A, L, R) shape key
    # churns for the whole run — FedCD's defining regime, and the one
    # pipelining targets (speculation overlaps the retraces)
    cfg = C.default_cfg(quantize_bits=8, max_models=m_cap,
                        milestones=(1, 3, 5),
                        late_delete_round=max(4, rounds // 2), **base)

    mesh = make_model_mesh(shards)   # shared across both sharded runs
    variants = [
        ("sharded_sync", EngineSpec(model_shards=shards, mesh=mesh)),
        ("sharded_pipelined", EngineSpec(model_shards=shards, mesh=mesh,
                                         pipeline=True)),
        ("fused_sync", EngineSpec()),
        ("fused_pipelined", EngineSpec(pipeline=True))]
    servers = {}
    total = {}
    for tag, spec in variants:
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, spec=spec)
        t0 = time.time()
        srv.run(rounds)
        total[tag] = time.time() - t0
        servers[tag] = srv

    live = [m.live_models for m in servers["sharded_sync"].metrics]
    lines = []
    for tag, _ in variants:
        med = float(np.median([servers[tag].metrics[r - 1].wall_s
                               for r in range(rounds // 2 + 1,
                                              rounds + 1)]))
        lines.append(C.csv_line(
            f"pipeline_round_wall_{tag}", total[tag] / rounds * 1e6,
            f"median_steady_us={med * 1e6:.0f};rounds={rounds};"
            f"steady_live={live[-1]};devices={cfg.n_devices};"
            f"shards={shards if 'sharded' in tag else 1}"))
    st = servers["sharded_pipelined"].pipeline_stats.as_dict()
    spec = max(st["speculated"], 1)
    lines.append(C.csv_line(
        "pipeline_speedup", 0.0,
        f"fused_pipelined_over_sharded_sync="
        f"{total['sharded_sync'] / total['fused_pipelined']:.2f}x;"
        f"sharded_pipelined_over_sharded_sync="
        f"{total['sharded_sync'] / total['sharded_pipelined']:.2f}x;"
        f"fused_pipelined_over_fused_sync="
        f"{total['fused_sync'] / total['fused_pipelined']:.2f}x;"
        f"repair_rate={st['repaired'] / spec:.2f};"
        f"hit_rate={st['hit'] / spec:.2f};"
        f"invalidated={st['invalidated']};discarded={st['discarded']};"
        f"skipped={st['skipped']};shards={shards}"))
    # pipelining must be a pure scheduling refactor: identical
    # population dynamics on the same seed
    for tag, _ in variants[1:]:
        other = [m.live_models for m in servers[tag].metrics]
        if other != live:
            raise AssertionError(
                f"pipeline divergence: {tag} live={other} sync={live}")
    return lines


def measure_sparse_eval(rounds: int = 16, model: str = "mlp",
                        quick: bool = False, crossover: float = 0.5):
    """Dense vs holder-only (sparse) validation scoring (DESIGN.md
    §10): identical seeded fused runs in the post-segregation regime
    (deletions active, so each surviving model is held by a shrinking
    clique and the active (model, device) matrix goes sparse), one with
    the planner's ``sparse_eval`` crossover enabled. Reports the
    dense/sparse round-wall ratio, the fraction of rounds the planner
    actually went sparse, and the final matrix density — the crossover
    where the pair form beats the dense GEMM's weight reuse is the
    number the ROADMAP eval item needs from a real accelerator."""
    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 8)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=4,
                    milestones=(1, 2), late_delete_round=3,
                    local_epochs=1)
    else:
        rounds = max(rounds, 12)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        base = dict(devices_per_round=6, milestones=(1, 2, 3),
                    late_delete_round=5, local_epochs=1)
    cfg = C.default_cfg(quantize_bits=8, **base)

    servers = {}
    total = {}
    for tag, sparse in (("dense", None), ("sparse", crossover)):
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH,
                          spec=EngineSpec(sparse_eval=sparse))
        t0 = time.time()
        srv.run(rounds)
        total[tag] = time.time() - t0
        servers[tag] = srv

    live = servers["dense"].registry.live_ids()
    active = servers["dense"].state.active
    density = (float(active[:, live].mean()) if live else 0.0)
    sparse_rounds = servers["sparse"].planner.sparse_rounds
    lines = []
    for tag in ("dense", "sparse"):
        med = float(np.median([servers[tag].metrics[r - 1].wall_s
                               for r in range(rounds // 2 + 1,
                                              rounds + 1)]))
        lines.append(C.csv_line(
            f"sparse_eval_round_wall_{tag}", total[tag] / rounds * 1e6,
            f"median_steady_us={med * 1e6:.0f};rounds={rounds};"
            f"devices={cfg.n_devices}"))
    lines.append(C.csv_line(
        "sparse_eval_ratio", 0.0,
        f"dense_over_sparse={total['dense'] / total['sparse']:.2f}x;"
        f"crossover={crossover};active_density={density:.3f};"
        f"sparse_rounds={sparse_rounds}/{rounds}"))
    other = [m.live_models for m in servers["sparse"].metrics]
    ref = [m.live_models for m in servers["dense"].metrics]
    if other != ref:
        raise AssertionError(
            f"sparse-eval divergence: sparse live={other} dense={ref}")
    return lines


def compare_semisync(rounds: int = 16, model: str = "mlp",
                     quick: bool = False):
    """Semi-synchronous rounds vs the full barrier under a heavy-tail
    straggler regime (DESIGN.md §12): identical seeded fused runs, one
    synchronous, one with a lognormal latency model (σ=2, so the slowest
    device in a cohort routinely takes several times the median), 75%
    quorum, and 5% mid-round dropouts. The headline number is VIRTUAL
    round time — Σ quorum-deadline waits vs Σ full-barrier waits on the
    SAME latency draws (both accumulated by the coordinator, so the
    ratio isolates the policy) — alongside the staleness histogram of
    folded updates, the buffer accounting, and the accuracy cost of
    discounted late folds."""
    from repro.data.scenarios import StragglerModel

    params, loss_fn, acc_fn = C.model_fns(model)
    if quick:
        rounds = max(rounds, 8)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        base = dict(n_devices=len(devs), devices_per_round=4,
                    milestones=(1, 2), late_delete_round=3,
                    local_epochs=1)
    else:
        rounds = max(rounds, 12)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        base = dict(devices_per_round=6, milestones=(1, 2, 3),
                    late_delete_round=5, local_epochs=1)
    cfg = C.default_cfg(quantize_bits=8, **base)
    straggler = StragglerModel(distribution="lognormal", sigma=2.0,
                               quorum=0.75, dropout_rate=0.05,
                               seed=cfg.seed)

    servers = {}
    total = {}
    for tag, spec in (("sync", EngineSpec()),
                      ("semisync", EngineSpec(straggler=straggler))):
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, spec=spec)
        t0 = time.time()
        srv.run(rounds)
        total[tag] = time.time() - t0
        servers[tag] = srv

    st = servers["semisync"].semisync_stats.as_dict()
    if not st["folded"]:
        raise AssertionError(
            f"semisync bench never folded a straggler: {st}")
    speedup = st["t_sync"] / max(st["t_semisync"], 1e-12)
    acc = {t: float(servers[t].metrics[-1].test_acc.mean())
           for t in servers}
    lines = []
    for tag in ("sync", "semisync"):
        med = float(np.median([servers[tag].metrics[r - 1].wall_s
                               for r in range(rounds // 2 + 1,
                                              rounds + 1)]))
        lines.append(C.csv_line(
            f"semisync_round_wall_{tag}", total[tag] / rounds * 1e6,
            f"median_steady_us={med * 1e6:.0f};rounds={rounds};"
            f"devices={cfg.n_devices};acc={acc[tag]:.3f}"))
    hist = ";".join(f"tau{k}={v}"
                    for k, v in st["staleness_hist"].items())
    lines.append(C.csv_line(
        "semisync_virtual_speedup", 0.0,
        f"sync_over_semisync={speedup:.2f}x;"
        f"t_sync={st['t_sync']:.1f};t_semisync={st['t_semisync']:.1f};"
        f"stragglers={st['stragglers']}/{st['dispatched']};"
        f"folded={st['folded']};expired={st['expired']};"
        f"dropouts={st['dropouts']};{hist or 'tau_none=0'};"
        f"acc_delta={acc['semisync'] - acc['sync']:+.3f}"))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare-engines", action="store_true",
                    help="time batched vs legacy round engines")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="with --compare-engines: also time the mesh-"
                         "sharded fused engine on N simulated devices")
    ap.add_argument("--pipeline", action="store_true",
                    help="time cross-round pipelined dispatch against "
                         "the synchronous engines (uses --mesh shards)")
    ap.add_argument("--sparse-eval", action="store_true",
                    help="time dense vs holder-only validation scoring")
    ap.add_argument("--semisync", action="store_true",
                    help="semi-synchronous rounds vs the full barrier "
                         "under a heavy-tail straggler regime")
    ap.add_argument("--data-mesh", action="store_true",
                    help="time the 2-D (model x data) mesh vs the 1-D "
                         "model mesh at 4 simulated devices (2x2 vs "
                         "4x1) plus a churn-regime row")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (small config, few rounds)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = []
    if args.compare_engines:
        out += compare_engines(args.rounds or (8 if args.quick else 20),
                               args.model, quick=args.quick)
        if args.mesh:
            out += compare_mesh(args.rounds or (8 if args.quick else 16),
                                args.model, shards=args.mesh,
                                quick=args.quick)
    elif args.mesh and not args.pipeline:
        out += compare_mesh(args.rounds or (8 if args.quick else 16),
                            args.model, shards=args.mesh,
                            quick=args.quick)
    if args.pipeline:
        out += compare_pipeline(args.rounds or (8 if args.quick else 16),
                                args.model, shards=args.mesh or 4,
                                quick=args.quick)
    if args.sparse_eval:
        out += measure_sparse_eval(args.rounds or (8 if args.quick
                                                   else 16),
                                   args.model, quick=args.quick)
    if args.semisync:
        out += compare_semisync(args.rounds or (8 if args.quick else 16),
                                args.model, quick=args.quick)
    if args.data_mesh:
        out += compare_datamesh(args.rounds or (8 if args.quick else 12),
                                args.model, quick=args.quick)
    if not out:
        out = run(args.rounds or (6 if args.quick else 30), args.model,
                  args.force or args.quick)
    for ln in out:
        print(ln)
