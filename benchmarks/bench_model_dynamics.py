"""Paper Figures 7, 8, 9: model-selection behaviour, active model counts
across bias levels, and the score-σ trajectory.

Fig 7: consensus preferred model per archetype over rounds — devices
should segregate by meta-archetype after the first milestone.
Fig 8/9: number of active (device, model) preferences and mean score σ,
swept over device bias ∈ {0.2 (IID-within-meta), 0.45, 0.65, 0.9}.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core.fedcd import FedCDServer


def run(rounds: int = 30, model: str = "mlp", force: bool = False):
    name = f"fig789_dynamics_{model}_{rounds}"
    cached = None if force else C.load_result(name)
    if cached is None:
        params, loss_fn, acc_fn = C.model_fns(model)
        by_bias = {}
        preferred = None
        metas = None
        for bias in (0.2, 0.45, 0.65, 0.9):
            devs, data = C.make_data("hierarchical", seed=0, bias=bias)
            cfg = C.default_cfg(milestones=(5, 15, 25))
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH)
            srv.run(rounds)
            by_bias[str(bias)] = {
                "active_models": [m.active_models for m in srv.metrics],
                "live_models": [m.live_models for m in srv.metrics],
                "score_std": [m.score_std for m in srv.metrics],
            }
            if bias == 0.65:
                preferred = [m.preferred.tolist() for m in srv.metrics]
                metas = [d.archetype // 5 for d in devs]
        cached = {"rounds": rounds, "by_bias": by_bias,
                  "preferred": preferred, "metas": metas}
        C.save_result(name, cached)

    # Fig 7 segregation purity at the end (bias 0.65 run)
    pref = np.array(cached["preferred"][-1])
    metas = np.array(cached["metas"])
    purity = 0.0
    for meta in (0, 1):
        p = pref[metas == meta]
        purity += np.max(np.bincount(p)) / len(p) / 2
    lines = [C.csv_line("fig7_meta_segregation_purity", 0.0,
                        f"purity={purity:.3f}")]
    for bias, r in cached["by_bias"].items():
        lines.append(C.csv_line(
            f"fig8_active_models_bias{bias}", 0.0,
            f"peak={max(r['active_models'])};final={r['active_models'][-1]};"
            f"final_live={r['live_models'][-1]}"))
        lines.append(C.csv_line(
            f"fig9_score_std_bias{bias}", 0.0,
            f"peak={max(r['score_std']):.3f};final={r['score_std'][-1]:.3f}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
