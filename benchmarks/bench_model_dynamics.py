"""Paper Figures 7, 8, 9: model-selection behaviour, active model counts
across bias levels, and the score-σ trajectory.

Fig 7: consensus preferred model per archetype over rounds — devices
should segregate by meta-archetype after the first milestone.
Fig 8/9: number of active (device, model) preferences and mean score σ,
swept over device bias ∈ {0.2 (IID-within-meta), 0.45, 0.65, 0.9}.

``--compare-engines`` instead times the batched round engine against the
legacy per-model loop on a multi-model population (milestones at rounds
1 and 2 → 4 live models) and reports the steady-state per-round speedup.
``--quick`` shrinks it to a CI smoke (10 devices, 2 measured rounds).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common as C
from repro.core.fedcd import FedCDServer


def run(rounds: int = 30, model: str = "mlp", force: bool = False):
    name = f"fig789_dynamics_{model}_{rounds}"
    cached = None if force else C.load_result(name)
    if cached is None:
        params, loss_fn, acc_fn = C.model_fns(model)
        by_bias = {}
        preferred = None
        metas = None
        for bias in (0.2, 0.45, 0.65, 0.9):
            devs, data = C.make_data("hierarchical", seed=0, bias=bias)
            cfg = C.default_cfg(milestones=(5, 15, 25))
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH)
            srv.run(rounds)
            by_bias[str(bias)] = {
                "active_models": [m.active_models for m in srv.metrics],
                "live_models": [m.live_models for m in srv.metrics],
                "score_std": [m.score_std for m in srv.metrics],
            }
            if bias == 0.65:
                preferred = [m.preferred.tolist() for m in srv.metrics]
                metas = [d.archetype // 5 for d in devs]
        cached = {"rounds": rounds, "by_bias": by_bias,
                  "preferred": preferred, "metas": metas}
        C.save_result(name, cached)

    # Fig 7 segregation purity at the end (bias 0.65 run)
    pref = np.array(cached["preferred"][-1])
    metas = np.array(cached["metas"])
    purity = 0.0
    for meta in (0, 1):
        p = pref[metas == meta]
        purity += np.max(np.bincount(p)) / len(p) / 2
    lines = [C.csv_line("fig7_meta_segregation_purity", 0.0,
                        f"purity={purity:.3f}")]
    for bias, r in cached["by_bias"].items():
        lines.append(C.csv_line(
            f"fig8_active_models_bias{bias}", 0.0,
            f"peak={max(r['active_models'])};final={r['active_models'][-1]};"
            f"final_live={r['live_models'][-1]}"))
        lines.append(C.csv_line(
            f"fig9_score_std_bias{bias}", 0.0,
            f"peak={max(r['score_std']):.3f};final={r['score_std'][-1]:.3f}"))
    return lines


def compare_engines(rounds: int = 8, model: str = "mlp",
                    quick: bool = False):
    """Time batched vs legacy on identical seeded runs with ≥4 live
    models (milestones at rounds 1 and 2 double the population twice).

    Warmup rounds (tracing + bucket compilation) are excluded: the
    steady-state figure is the median per-round wall over the rounds
    after the last milestone, where both engines run fully compiled.
    """
    if quick:
        rounds = max(rounds, 6)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65,
                                 devices_per_archetype=1)
        cfg = C.default_cfg(n_devices=len(devs), devices_per_round=5,
                            milestones=(1, 2), late_delete_round=rounds + 1)
    else:
        rounds = max(rounds, 6)
        devs, data = C.make_data("hierarchical", seed=0, bias=0.65)
        cfg = C.default_cfg(milestones=(1, 2), late_delete_round=rounds + 1)
    params, loss_fn, acc_fn = C.model_fns(model)

    servers = {}
    total = {}
    for engine in ("legacy", "batched"):
        srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH, engine=engine)
        t0 = time.time()
        srv.run(rounds)
        total[engine] = time.time() - t0
        servers[engine] = srv

    # both engines walk the same RNG stream -> identical model dynamics,
    # so per-round timings align round for round
    live = [m.live_models for m in servers["batched"].metrics]
    # the population mutates through rounds 1-3 (two milestones + first
    # deletions), each mutation re-bucketing the work batch; every bucket
    # is compiled by round 4, so steady state starts at round 5
    steady = list(range(5, rounds + 1)) or [rounds]
    med = {e: float(np.median([servers[e].metrics[t - 1].wall_s
                               for t in steady])) for e in servers}
    speedup = med["legacy"] / max(med["batched"], 1e-12)
    lines = [
        C.csv_line(
            "engine_round_wall_batched", med["batched"] * 1e6,
            f"rounds={rounds};live_models={max(live)};"
            f"devices={cfg.n_devices}"),
        C.csv_line(
            "engine_round_wall_legacy", med["legacy"] * 1e6,
            f"rounds={rounds};live_models={max(live)};"
            f"devices={cfg.n_devices}"),
        C.csv_line(
            "engine_speedup", 0.0,
            f"batched_over_legacy={speedup:.2f}x;"
            f"total_legacy_s={total['legacy']:.2f};"
            f"total_batched_s={total['batched']:.2f}"),
    ]
    # smoke check: the engines must agree on the population dynamics
    legacy_live = [m.live_models for m in servers["legacy"].metrics]
    if legacy_live != live:
        raise AssertionError(
            f"engine divergence: legacy live={legacy_live} batched={live}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare-engines", action="store_true",
                    help="time batched vs legacy round engines")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (small config, few rounds)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.compare_engines:
        out = compare_engines(args.rounds or (6 if args.quick else 8),
                              args.model, quick=args.quick)
    else:
        out = run(args.rounds or (6 if args.quick else 30), args.model,
                  args.force or args.quick)
    for ln in out:
        print(ln)
