"""Kernel microbenchmarks: Pallas (interpret on CPU — numbers are
correctness-path timings, NOT TPU perf) vs the jnp oracle, plus payload
size accounting which IS hardware-independent."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.quantize import compressed_bytes
from repro.kernels.quantize import ops as qops, ref as qref
from repro.kernels.weighted_agg import ops as wops, ref as wref


def _time(fn, *args, reps=5):
    fn(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(force: bool = False, quick: bool = False):
    qshape = (64, 2048) if quick else (512, 4096)
    N, D = (8, 4096) if quick else (15, 512 * 256)
    n_models = 4

    x = jax.random.normal(jax.random.PRNGKey(0), qshape)
    lines = []
    us = _time(lambda a: qops.quantize(a)[0], x)
    lines.append(C.csv_line("kernel_quantize_pallas_interp", us,
                            f"shape={qshape[0]}x{qshape[1]}"))
    us = _time(lambda a: qref.quantize_ref(a)[0], x)
    lines.append(C.csv_line("kernel_quantize_jnp_ref", us,
                            f"shape={qshape[0]}x{qshape[1]}"))

    u = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    w = jax.random.uniform(jax.random.PRNGKey(2), (N,))
    d = jnp.sum(w)
    us = _time(wops.weighted_agg, u, w, d)
    lines.append(C.csv_line("kernel_weighted_agg_pallas_interp", us,
                            f"N={N},D={D}"))
    us = _time(lambda a, b, c: wref.weighted_agg_ref(a, b, c), u, w, d)
    lines.append(C.csv_line("kernel_weighted_agg_jnp_ref", us,
                            f"N={N},D={D}"))

    # multi-model path: the batched engine's per-round aggregation —
    # all models from one work batch in one fused call vs M single calls
    wm = np.zeros((n_models, N), np.float32)
    for j in range(n_models):
        wm[j, j::n_models] = np.asarray(w)[j::n_models]
    wm = jnp.asarray(wm)
    dm = jnp.maximum(jnp.sum(wm, axis=1), 1e-12)
    us_multi = _time(wops.multi_weighted_agg, u, wm, dm)
    lines.append(C.csv_line("kernel_multi_weighted_agg_fused", us_multi,
                            f"M={n_models},B={N},D={D}"))
    us_loop = _time(
        lambda a, ws, ds: [wops.weighted_agg(a, ws[j], ds[j])
                           for j in range(n_models)][-1], u, wm, dm)
    lines.append(C.csv_line(
        "kernel_multi_weighted_agg_per_model_loop", us_loop,
        f"M={n_models},B={N},D={D};fused_speedup="
        f"{us_loop / max(us_multi, 1e-9):.2f}x"))

    q, s = qref.quantize_ref(u)
    us = _time(wops.dequant_agg, q, s, w, d)
    lines.append(C.csv_line("kernel_dequant_agg_fused_interp", us,
                            f"N={N},D={D}"))

    tree = {"w": x}
    f32 = sum(leaf.size * 4 for leaf in jax.tree.leaves(tree))
    lines.append(C.csv_line(
        "quantize_payload_int8", 0.0,
        f"bytes={compressed_bytes(tree, 8)};f32_bytes={f32};"
        f"ratio={f32 / compressed_bytes(tree, 8):.2f}"))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (small shapes)")
    args = ap.parse_args()
    for ln in run(quick=args.quick):
        print(ln)
