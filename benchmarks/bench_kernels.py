"""Kernel microbenchmarks: Pallas (interpret on CPU — numbers are
correctness-path timings, NOT TPU perf) vs the jnp oracle, plus payload
size accounting which IS hardware-independent."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.quantize import compressed_bytes
from repro.kernels.quantize import ops as qops, ref as qref
from repro.kernels.weighted_agg import ops as wops, ref as wref


def _time(fn, *args, reps=5):
    fn(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(force: bool = False):
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 4096))
    lines = []
    us = _time(lambda a: qops.quantize(a)[0], x)
    lines.append(C.csv_line("kernel_quantize_pallas_interp", us,
                            "shape=512x4096"))
    us = _time(lambda a: qref.quantize_ref(a)[0], x)
    lines.append(C.csv_line("kernel_quantize_jnp_ref", us, "shape=512x4096"))

    u = jax.random.normal(jax.random.PRNGKey(1), (15, 512 * 256))
    w = jax.random.uniform(jax.random.PRNGKey(2), (15,))
    d = jnp.sum(w)
    us = _time(wops.weighted_agg, u, w, d)
    lines.append(C.csv_line("kernel_weighted_agg_pallas_interp", us,
                            "N=15,D=131072"))
    us = _time(lambda a, b, c: wref.weighted_agg_ref(a, b, c), u, w, d)
    lines.append(C.csv_line("kernel_weighted_agg_jnp_ref", us,
                            "N=15,D=131072"))

    q, s = qref.quantize_ref(u)
    us = _time(wops.dequant_agg, q, s, w, d)
    lines.append(C.csv_line("kernel_dequant_agg_fused_interp", us,
                            "N=15,D=131072"))

    tree = {"w": x}
    f32 = sum(l.size * 4 for l in jax.tree.leaves(tree))
    lines.append(C.csv_line(
        "quantize_payload_int8", 0.0,
        f"bytes={compressed_bytes(tree, 8)};f32_bytes={f32};"
        f"ratio={f32 / compressed_bytes(tree, 8):.2f}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
