"""Paper §3.6: communication cost accounting — cumulative transport bytes
for FedCD (multi-model, score-weighted participation) vs FedAvg, with and
without int8 compression."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.fedavg import FedAvgServer
from repro.core.fedcd import FedCDServer


def run(rounds: int = 25, model: str = "mlp", force: bool = False):
    name = f"comm_costs_{model}_{rounds}"
    cached = None if force else C.load_result(name)
    if cached is None:
        devs, data = C.make_data("hierarchical", seed=0)
        params, loss_fn, acc_fn = C.model_fns(model)
        out = {}
        for tag, bits in (("f32", 0), ("int8", 8)):
            cfg = C.default_cfg(quantize_bits=bits, milestones=(5, 15))
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH)
            srv.run(rounds)
            out[f"fedcd_{tag}"] = [int(m.comm_bytes) for m in srv.metrics]
        cfg = C.default_cfg(milestones=(5, 15))
        fa = FedAvgServer(cfg, params, loss_fn, acc_fn, data,
                          batch_size=C.BATCH)
        fa.run(rounds)
        out["fedavg_f32"] = [int(m.comm_bytes) for m in fa.metrics]
        cached = {"rounds": rounds, "series": out}
        C.save_result(name, cached)
    s = cached["series"]
    lines = []
    for k, v in s.items():
        lines.append(C.csv_line(f"comm_total_{k}", 0.0,
                                f"MB={sum(v)/1e6:.1f};per_round_MB="
                                f"{sum(v)/len(v)/1e6:.2f}"))
    overhead = sum(s["fedcd_f32"]) / max(sum(s["fedavg_f32"]), 1)
    lines.append(C.csv_line("comm_fedcd_overhead_vs_fedavg", 0.0,
                            f"x={overhead:.2f}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
