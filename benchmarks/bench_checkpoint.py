"""Elastic-checkpoint overhead (DESIGN.md §13): what a snapshot costs
relative to a round of training, at realistic cadences.

Measures, on the fused engine at the default 30-device config:

* ``ckpt_save``    — one full ``save_server_state`` (quiesce + host
  gather + atomic npz + manifest), with the snapshot's on-disk size and
  the save cost as a percentage of round wall-clock at snapshot
  cadences 1 / 5 / 20 (the derived column CI tracks);
* ``ckpt_restore`` — one ``restore_server_state`` into a freshly
  constructed server (verify checksums + re-place ids + re-upload).

Run directly or via ``python -m benchmarks.run --only checkpoint``.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks import common as C

CADENCES = (1, 5, 20)


def run(rounds: int = 16, model: str = "mlp", quick: bool = False):
    from repro.checkpoint.state import (ARRAYS, MANIFEST,
                                        restore_server_state,
                                        save_server_state)
    from repro.core.fedcd import FedCDServer
    from repro.core.spec import EngineSpec

    params, loss, acc = C.model_fns(model)
    _, data = C.make_data("hierarchical")
    cfg = C.default_cfg(milestones=(3, 6),
                        late_delete_round=max(rounds // 2, 8))

    srv = FedCDServer(cfg, params, loss, acc, data, batch_size=C.BATCH,
                      spec=EngineSpec())
    srv.run(2)                                   # compile + warm caches
    n = rounds - 2
    t0 = time.perf_counter()
    srv.run(rounds)                              # continues from round 3
    t_round = (time.perf_counter() - t0) / max(n, 1)

    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        reps = 2 if quick else 4
        t_saves = []
        for i in range(reps):
            t1 = time.perf_counter()
            save_server_state(srv, os.path.join(tmp, f"s{i}"))
            t_saves.append(time.perf_counter() - t1)
        t_save = min(t_saves)
        nbytes = sum(os.path.getsize(os.path.join(tmp, "s0", f))
                     for f in (ARRAYS, MANIFEST))

        fresh = FedCDServer(cfg, params, loss, acc, data,
                            batch_size=C.BATCH, spec=EngineSpec())
        t_restores = []
        for i in range(reps):
            t1 = time.perf_counter()
            restore_server_state(fresh, os.path.join(tmp, f"s{i % reps}"))
            t_restores.append(time.perf_counter() - t1)
        t_restore = min(t_restores)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    pct = ";".join(
        f"pct_round@{c}={100.0 * t_save / (c * t_round):.2f}"
        for c in CADENCES)
    return [
        C.csv_line("ckpt_save", t_save * 1e6,
                   f"bytes={nbytes};round_us={t_round * 1e6:.0f};{pct}"),
        C.csv_line("ckpt_restore", t_restore * 1e6,
                   f"save_us={t_save * 1e6:.0f}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for ln in run(args.rounds, args.model, quick=args.quick):
        print(ln)
