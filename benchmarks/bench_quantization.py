"""Paper Figure 6: effect of transport quantization on FedCD accuracy.

Levels: none (f32), int8, int4 — the paper's claim is that quantization
has no significant accuracy effect.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core.fedcd import FedCDServer


def run(rounds: int = 25, model: str = "mlp", force: bool = False):
    name = f"fig6_quantization_{model}_{rounds}"
    cached = None if force else C.load_result(name)
    if cached is None:
        results = {}
        devs, data = C.make_data("hierarchical", seed=0)
        params, loss_fn, acc_fn = C.model_fns(model)
        for bits in (0, 8, 4):
            cfg = C.default_cfg(quantize_bits=bits, milestones=(5, 15))
            srv = FedCDServer(cfg, params, loss_fn, acc_fn, data,
                              batch_size=C.BATCH)
            srv.run(rounds)
            results[str(bits)] = {
                "acc": [float(m.test_acc.mean()) for m in srv.metrics],
                "comm_bytes": int(sum(m.comm_bytes for m in srv.metrics)),
            }
        cached = {"rounds": rounds, "levels": results}
        C.save_result(name, cached)
    lines = []
    base = cached["levels"]["0"]["acc"][-1]
    for bits in ("0", "8", "4"):
        r = cached["levels"][bits]
        tag = "f32" if bits == "0" else f"int{bits}"
        lines.append(C.csv_line(
            f"fig6_acc_{tag}", 0.0,
            f"acc={r['acc'][-1]:.3f};delta_vs_f32={r['acc'][-1]-base:+.3f};"
            f"comm_MB={r['comm_bytes']/1e6:.0f}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
