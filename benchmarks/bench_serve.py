"""Serving gateway bench (DESIGN.md §15–16): grouped continuous
batching vs the serial single-request path, chunked prefill vs the old
token-at-a-time loop, a train/serve interleave mode, and (``run_spec``,
``--only spec``) the PR 10 additions — speculative decoding with
cluster-shared drafts, paged int8 KV pools, and admission control.

Replays a Zipf-over-devices request trace against a trained FedCD LM
population (4 live models) and reports p50/p99 TTFT (queue-relative, so
the serial path's head-of-line blocking is visible), tokens/s, and
batching efficiency. The acceptance bar: grouped decode ≥ 2x the serial
path's tokens/s at 4 live models and 32 concurrent requests.

The spec-decode rows report acceptance rate, emitted tokens per verify
round, and per-round dispatch overhead alongside tokens/s: on this
CPU-only container both draft and target rounds are host-dispatch
bound at tiny model sizes, so wall-clock speedup is confounded (see
DESIGN.md §16 — the tokens-per-dispatch ratio is the transferable
number). The paged-KV row pins the int8 shrink bar (>= 3.5x resident
bytes vs dense fp32 at equal lanes).

Run directly (``--spec`` / ``--paged-kv`` for the PR 10 benches) or via
``python -m benchmarks.run --only serve,spec``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common as C

MAX_LEN = 64


def _zipf_devices(n_dev: int, n_req: int, rng, a: float = 1.2):
    """Zipf-over-devices: a few devices dominate the trace (their
    cluster's model group stays hot), the tail trickles in."""
    ranks = rng.permutation(n_dev)
    p = 1.0 / (np.arange(1, n_dev + 1) ** a)
    return ranks[rng.choice(n_dev, size=n_req, p=p / p.sum())]


def _population(rounds: int):
    from repro.config import ArchConfig, FedCDConfig
    from repro.federated.llm import FedLLMTrainer

    arch = ArchConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    # 4 archetypes + 3 milestones: the population settles at 4 live
    # models (the regime the acceptance bar names); no late deletes so
    # the timed trace serves a stable population
    fed = FedCDConfig(n_devices=8, devices_per_round=6, score_window=3,
                      milestones=(1, 2, 3), late_delete_round=10_000,
                      max_models=8, lr=0.05, seed=0)
    tr = FedLLMTrainer(arch, fed, 8, 2, 16, n_archetypes=4, seed=0)
    tr.run(rounds)
    return arch, tr


def _serial(arch, tr, trace, prompts, max_new: int):
    """The pre-gateway path: one request at a time, per-request bank-row
    param gather, token-at-a-time prefill AND decode, host argmax."""
    from repro.launch.steps import make_serve_step
    from repro.models import transformer as tf
    from repro.serve import RoutingTable

    step = jax.jit(make_serve_step(arch))
    rt = RoutingTable(tr.registry, lambda: tr.state)

    def one(d, prompt):
        params = tr.registry.params[rt.resolve(int(d))]
        caches = tf.init_lm_caches(arch, 1, MAX_LEN)
        logits = None
        for t in range(prompt.size):
            logits, caches = step(params, caches,
                                  jnp.asarray([[prompt[t]]], jnp.int32))
        toks = [int(np.argmax(np.asarray(logits)[0]))]
        first_t = time.perf_counter()
        for _ in range(max_new - 1):
            logits, caches = step(params, caches,
                                  jnp.asarray([[toks[-1]]], jnp.int32))
            toks.append(int(np.argmax(np.asarray(logits)[0])))
        return toks, first_t

    one(trace[0], prompts[0])                       # compile warm-up
    t0 = time.perf_counter()
    ttfts, n_tok = [], 0
    for d, p in zip(trace, prompts):
        toks, first_t = one(d, p)
        ttfts.append(first_t - t0)                  # queue-relative
        n_tok += len(toks)
    wall = time.perf_counter() - t0
    return wall, n_tok, np.asarray(ttfts)


def _grouped(arch, tr, trace, prompts, max_new: int, lanes: int,
             chunk: int):
    from repro.serve import ServeGateway

    gw = ServeGateway(arch, tr.registry, lambda: tr.state,
                      max_len=MAX_LEN, lanes=lanes, chunk=chunk)
    for d, p in zip(trace, prompts):                # compile warm-up
        gw.submit(int(d), p, max_new)
    gw.drain()
    t0 = time.perf_counter()
    reqs = [gw.submit(int(d), p, max_new) for d, p in zip(trace, prompts)]
    gw.drain()
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in reqs)
    ttfts = np.asarray(sorted(r.first_token_t - t0 for r in reqs))
    effs = [g.batching_efficiency() for g in gw.groups.values()
            if g.steps]
    return gw, wall, n_tok, ttfts, float(np.mean(effs))


def _prefill_speed(arch, tr, rng, P: int = 48, chunk: int = 16,
                   reps: int = 5):
    """Chunked jitted prefill vs the old repeated-decode prompt loop."""
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.models import transformer as tf

    params = tr.registry.params[tr.registry.live_ids()[0]]
    prefill = jax.jit(make_prefill_step(arch))
    step = jax.jit(make_serve_step(arch))
    prompt = rng.integers(0, arch.vocab_size, P).astype(np.int32)

    def chunked():
        caches = tf.init_lm_caches(arch, 1, MAX_LEN)
        logits = None
        for s in range(0, P, chunk):
            logits, caches = prefill(
                params, caches, jnp.asarray(prompt[None, s:s + chunk]),
                chunk)
        jax.block_until_ready(logits)

    def token_loop():
        caches = tf.init_lm_caches(arch, 1, MAX_LEN)
        logits = None
        for t in range(P):
            logits, caches = step(params, caches,
                                  jnp.asarray([[prompt[t]]], jnp.int32))
        jax.block_until_ready(logits)

    out = {}
    for name, fn in (("chunked", chunked), ("token_loop", token_loop)):
        fn()                                        # compile warm-up
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            walls.append(time.perf_counter() - t0)
        out[name] = float(np.median(walls))
    return out


def _interleave(tr, gw, start_round: int, n_rounds: int, n_req: int,
                max_new: int, rng):
    """Serve between training rounds: each round adopts the trainer's
    new bank via ``sync`` (score-drift re-route + pool reconcile), then
    drains a fresh trace slice."""
    n_tok, serve_wall, rerouted = 0, 0.0, 0
    t_all = time.perf_counter()
    for i in range(n_rounds):
        tr.run_round(start_round + 1 + i)
        out = gw.sync()
        rerouted += len(out["rerouted"])
        trace = _zipf_devices(8, n_req, rng)
        t0 = time.perf_counter()
        reqs = [gw.submit(int(d), rng.integers(0, 64, 12), max_new)
                for d in trace]
        gw.drain()
        serve_wall += time.perf_counter() - t0
        n_tok += sum(len(r.tokens) for r in reqs)
    wall = time.perf_counter() - t_all
    return wall, serve_wall, n_tok, rerouted


def run(quick: bool = False):
    rounds = 6 if quick else 10
    n_req = 32
    max_new = 8 if quick else 16
    lanes, chunk = 8, 8
    rng = np.random.default_rng(0)

    arch, tr = _population(rounds)
    live = len(tr.registry.live_ids())
    trace = _zipf_devices(8, n_req, rng)
    prompts = [rng.integers(0, arch.vocab_size, 12).astype(np.int32)
               for _ in range(n_req)]

    s_wall, s_tok, s_ttft = _serial(arch, tr, trace, prompts, max_new)
    gw, g_wall, g_tok, g_ttft, eff = _grouped(arch, tr, trace, prompts,
                                              max_new, lanes, chunk)
    st0 = gw.stats()["pools"]
    speedup = (g_tok / g_wall) / (s_tok / s_wall)
    pre = _prefill_speed(arch, tr, rng)
    i_wall, i_serve, i_tok, i_rerouted = _interleave(
        tr, gw, rounds, 2 if quick else 3, 8, max_new, rng)
    st = gw.stats()

    def pct(x, q):
        return float(np.percentile(x, q)) * 1e3

    return [
        C.csv_line("serve_serial", s_wall / s_tok * 1e6,
                   f"tokens_s={s_tok / s_wall:.1f};"
                   f"p50_ttft_ms={pct(s_ttft, 50):.1f};"
                   f"p99_ttft_ms={pct(s_ttft, 99):.1f};"
                   f"reqs={n_req};live={live}"),
        C.csv_line("serve_grouped", g_wall / g_tok * 1e6,
                   f"serial_x={speedup:.2f};"
                   f"tokens_s={g_tok / g_wall:.1f};"
                   f"p50_ttft_ms={pct(g_ttft, 50):.1f};"
                   f"p99_ttft_ms={pct(g_ttft, 99):.1f};"
                   f"batch_eff={eff:.2f};live={live};"
                   f"lanes={lanes};reqs={n_req};"
                   f"kv_bytes={st0['bytes']};"
                   f"kv_bytes_in_use={st0['bytes_in_use']}"),
        C.csv_line("serve_prefill_chunked", pre["chunked"] * 1e6,
                   f"tokenloop_x={pre['token_loop'] / pre['chunked']:.2f};"
                   f"prompt=48;chunk=16"),
        C.csv_line("serve_interleave", i_wall * 1e6,
                   f"serve_tokens_s={i_tok / i_serve:.1f};"
                   f"serve_frac={i_serve / i_wall:.2f};"
                   f"rerouted={i_rerouted};"
                   f"rebuilds={st['routing']['rebuilds']}"),
    ]


def _gateway_trace(arch, tr, trace, prompts, max_new, lanes, chunk, **kw):
    """Warm-compile + time one gateway configuration over the trace."""
    from repro.serve import ServeGateway

    gw = ServeGateway(arch, tr.registry, lambda: tr.state,
                      max_len=MAX_LEN, lanes=lanes, chunk=chunk, **kw)
    for d, p in zip(trace, prompts):                # compile warm-up
        gw.submit(int(d), p, max_new)
    gw.drain()
    t0 = time.perf_counter()
    reqs = [gw.submit(int(d), p, max_new) for d, p in zip(trace, prompts)]
    gw.drain()
    wall = time.perf_counter() - t0
    return gw, wall, sum(len(r.tokens) for r in reqs)


def run_spec(quick: bool = False, k: int = 4, draft_layers: int = 1,
             spec: bool = True, paged: bool = True):
    """PR 10 rows: speculative decode (``--spec``), paged int8 KV
    (``--paged-kv``) and admission control, all against the grouped
    gateway baseline on the same Zipf trace."""
    from repro.serve import (KVPool, PagedKVPool, RequestRejected,
                             ServeGateway)

    rounds = 6 if quick else 10
    n_req = 24 if quick else 32
    max_new = 8 if quick else 16
    lanes, chunk = 8, 8
    rng = np.random.default_rng(0)
    arch, tr = _population(rounds)
    live = len(tr.registry.live_ids())
    trace = _zipf_devices(8, n_req, rng)
    prompts = [rng.integers(0, arch.vocab_size, 12).astype(np.int32)
               for _ in range(n_req)]

    _, b_wall, b_tok = _gateway_trace(arch, tr, trace, prompts, max_new,
                                      lanes, chunk)
    base_tps = b_tok / b_wall
    lines = [C.csv_line("serve_spec_baseline", b_wall / b_tok * 1e6,
                        f"tokens_s={base_tps:.1f};live={live};"
                        f"lanes={lanes};reqs={n_req}")]

    if spec:
        gw, wall, tok = _gateway_trace(arch, tr, trace, prompts, max_new,
                                       lanes, chunk, spec_k=k,
                                       draft_layers=draft_layers)
        sp = gw.stats()["spec"]
        # tokens a lane emits per verify round vs the 2 dispatches the
        # round costs: the CPU-portable speedup number (run docstring)
        tok_per_round = 1.0 + sp["acceptance_rate"] * k
        lines.append(C.csv_line(
            "serve_spec_decode", wall / tok * 1e6,
            f"grouped_x={(tok / wall) / base_tps:.2f};"
            f"tokens_s={tok / wall:.1f};k={k};"
            f"draft_layers={sp['draft_layers']};"
            f"acceptance={sp['acceptance_rate']:.3f};"
            f"lane_tokens_per_round={tok_per_round:.2f};"
            f"dispatches_per_round=2;"
            f"draft_bytes={sp['draft_bytes']}"))

    if paged:
        gw, wall, tok = _gateway_trace(arch, tr, trace, prompts, max_new,
                                       lanes, chunk, paged=True)
        pg = gw.stats()["pools"]
        dense = KVPool(arch, lanes=lanes, max_len=MAX_LEN)
        pool = PagedKVPool(arch, lanes=lanes, max_len=MAX_LEN)
        for _ in range(lanes):
            pool.acquire()                          # fully occupied
        shrink = dense.nbytes() / pool.nbytes_in_use()
        lines.append(C.csv_line(
            "serve_paged_kv", wall / tok * 1e6,
            f"grouped_x={(tok / wall) / base_tps:.2f};"
            f"tokens_s={tok / wall:.1f};"
            f"kv_shrink_x={shrink:.2f};"
            f"dense_bytes={dense.nbytes()};"
            f"paged_bytes_in_use={pool.nbytes_in_use()};"
            f"pages_reserved={pg['pages']['pages_reserved']}"))
        assert shrink >= 3.5, f"paged int8 shrink {shrink:.2f}x < 3.5x"

    # admission control: a burst beyond queue capacity must shed load
    gw = ServeGateway(arch, tr.registry, lambda: tr.state,
                      max_len=MAX_LEN, lanes=lanes, chunk=chunk,
                      max_queue=4)
    accepted = rejected = 0
    t0 = time.perf_counter()
    for d, p in zip(trace, prompts):
        try:
            gw.submit(int(d), p, max_new)
            accepted += 1
        except RequestRejected:
            rejected += 1
    gw.drain()
    wall = time.perf_counter() - t0
    adm = gw.stats()["admission"]
    lines.append(C.csv_line(
        "serve_admission", wall / max(accepted, 1) * 1e6,
        f"reject_rate={rejected / n_req:.2f};accepted={accepted};"
        f"rejected_overload={adm['rejected_overload']};"
        f"rejected_rate={adm['rejected_rate']};"
        f"max_queue=4;burst={n_req}"))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="run the speculative-decode rows instead")
    ap.add_argument("--paged-kv", action="store_true",
                    help="run the paged int8 KV rows instead")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.spec or args.paged_kv:
        lines = run_spec(quick=args.quick, spec=args.spec,
                         paged=args.paged_kv)
    else:
        lines = run(quick=args.quick)
    for line in lines:
        print(line)
