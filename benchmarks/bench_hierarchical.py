"""Paper Figure 1 (a+b) + Figure 2: hierarchical archetypes.

FedCD vs FedAvg test accuracy per archetype over rounds, and the
round-to-round oscillation comparison.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C


def run(rounds: int = 40, model: str = "mlp", force: bool = False,
        engine: str = "fused"):
    # cache key always embeds the engine: PR 1 cached the batched engine
    # under a bare suffix, so an empty suffix would serve stale batched
    # results as fused on machines holding old caches
    suffix = f"_{engine}"
    name = f"fig1_hierarchical_{model}_{rounds}{suffix}"
    cached = None if force else C.load_result(name)
    if cached is None:
        t0 = time.time()
        cfg = C.default_cfg()
        fedcd, fedavg, devs = C.run_pair("hierarchical", rounds, cfg,
                                         model=model, engine=engine)
        cached = {
            "rounds": rounds,
            "fedcd_per_archetype": C.per_archetype_curves(fedcd.metrics,
                                                          devs),
            "fedcd_mean": [float(m.test_acc.mean()) for m in fedcd.metrics],
            "fedavg_mean": [float(m.test_acc.mean()) for m in fedavg.metrics],
            "fedcd_osc": C.oscillation(
                [float(m.test_acc.mean()) for m in fedcd.metrics]),
            "fedavg_osc": C.oscillation(
                [float(m.test_acc.mean()) for m in fedavg.metrics]),
            "live_models": [m.live_models for m in fedcd.metrics],
            "wall_s": time.time() - t0,
            "fedcd_wall_s": sum(m.wall_s for m in fedcd.metrics),
            "fedavg_wall_s": sum(m.wall_s for m in fedavg.metrics),
        }
        C.save_result(name, cached)
    cd, avg = cached["fedcd_mean"][-1], cached["fedavg_mean"][-1]
    osc_cd = np.mean(cached["fedcd_osc"][-10:])
    osc_avg = np.mean(cached["fedavg_osc"][-10:])
    lines = [
        C.csv_line("fig1b_final_acc_fedcd",
                   cached["wall_s"] * 1e6 / max(cached["rounds"], 1),
                   f"acc={cd:.3f}"),
        C.csv_line("fig1b_final_acc_fedavg", 0.0, f"acc={avg:.3f}"),
        C.csv_line("fig1_gap", 0.0, f"fedcd_minus_fedavg={cd - avg:+.3f}"),
        C.csv_line("fig2_osc_last10_fedcd", 0.0, f"osc={osc_cd:.4f}"),
        C.csv_line("fig2_osc_last10_fedavg", 0.0, f"osc={osc_avg:.4f}"),
    ]
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
